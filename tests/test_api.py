"""The public ``repro.api`` facade: specs, compile() -> Deployment,
artifact serialization, and the legacy-kwarg deprecation shims.

Pins the contract of the API redesign: the facade produces plans
identical to the legacy entry points, every artifact JSON round-trips
exactly, and a saved deployment reloads with zero re-planning or
re-calibration while behaving bit-identically."""

import json
import warnings

import jax
import numpy as np
import pytest

import repro
from repro.api import (DeploySpec, ExecSpec, PlanSpec, artifacts,
                       reset_legacy_warnings)
from repro.core import (CostTable, make_pi_cluster, plan, replan, simulate)
from repro.core.partition import PartitionResult
from repro.models.cnn import zoo
from repro.serving import PipelineServer
from repro.runtime import PipelineRuntime


def _tiny(name, size=64, scale=0.25):
    return zoo.build(name, input_size=(size, size), scale=scale)


def _canon(pico) -> dict:
    """Plan payload with the (non-deterministic) wall-time scrubbed."""
    d = artifacts.plan_to_dict(pico)
    d["partition"]["wall_time_s"] = 0.0
    d["pipeline"]["wall_time_s"] = 0.0
    return d


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        PlanSpec(t_lim=0.0)
    with pytest.raises(ValueError):
        PlanSpec(max_diameter=0)
    with pytest.raises(ValueError):
        PlanSpec(n_split=1)
    with pytest.raises(ValueError):
        ExecSpec(mode="sideways")
    with pytest.raises(ValueError):
        ExecSpec(cache_size=0)
    with pytest.raises(ValueError):
        DeploySpec(max_batch=0)
    with pytest.raises(ValueError):
        DeploySpec(ewma_beta=0.0)


@pytest.mark.parametrize("spec", [
    PlanSpec(), PlanSpec(t_lim=0.25, max_diameter=3, n_split=4),
    ExecSpec(), ExecSpec(backend="xla", mode="eager", donate=True,
                         cache_size=8, calibrate=True, calibrate_iters=2),
    DeploySpec(), DeploySpec(seed=3, max_batch=4, compute_noise=0.1,
                             migration_bandwidth=1e9),
])
def test_spec_json_roundtrip(spec):
    s = spec.to_json()
    json.loads(s)                       # strict JSON (inf spelled out)
    assert type(spec).from_json(s) == spec


def test_spec_json_rejects_garbage():
    with pytest.raises(ValueError):
        PlanSpec.from_dict({"kind": "ExecSpec", "version": 1})
    with pytest.raises(ValueError):
        PlanSpec.from_dict({"kind": "PlanSpec", "version": 99})
    with pytest.raises(ValueError):
        PlanSpec.from_dict({"kind": "PlanSpec", "version": 1, "nope": 1})


def test_spec_inf_is_strict_json():
    s = PlanSpec(t_lim=float("inf")).to_json()
    assert "Infinity" in s and json.loads(s)["t_lim"] == "Infinity"
    assert PlanSpec.from_json(s).t_lim == float("inf")


def test_artifact_nan_is_strict_json():
    table = CostTable({frozenset({"a"}): float("nan")}, default=1.0)
    s = artifacts.cost_table_to_json(table)
    json.loads(s, parse_constant=lambda c: pytest.fail(f"bare {c} in JSON"))
    back = artifacts.cost_table_from_json(s)
    assert np.isnan(back.ratios[frozenset({"a"})])


def test_deploy_spec_maps_to_runtime_config():
    spec = DeploySpec(seed=7, max_batch=3, drift_threshold=0.5, trace=True)
    cfg = spec.to_runtime_config()
    assert (cfg.seed, cfg.max_batch, cfg.drift_threshold, cfg.trace) \
        == (7, 3, 0.5, True)


# ---------------------------------------------------------------------------
# facade vs legacy equivalence (the model zoo)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,scale", [
    ("vgg16", 0.125), ("squeezenet", 0.25), ("mobilenetv3", 0.25),
    ("resnet34", 0.125), ("inceptionv3", 0.25),
])
def test_compile_matches_legacy_plan(name, scale):
    m = _tiny(name, scale=scale)
    cluster = make_pi_cluster([1.5, 1.0, 0.8])
    legacy = plan(m.graph, cluster, m.input_size)
    dep = repro.compile(m, cluster)
    assert _canon(dep.pico) == _canon(legacy)


def test_compile_spec_knobs_equal_legacy_kwargs():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.2, 1.0])
    reset_legacy_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = plan(m.graph, cluster, m.input_size, t_lim=0.02,
                      max_diameter=3, n_split=4)
    dep = repro.compile(m, cluster,
                        PlanSpec(t_lim=0.02, max_diameter=3, n_split=4))
    assert _canon(dep.pico) == _canon(legacy)


def test_plan_rejects_spec_plus_legacy_kwargs():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    with pytest.raises(TypeError):
        plan(m.graph, cluster, m.input_size, t_lim=0.5, spec=PlanSpec())


# ---------------------------------------------------------------------------
# artifact round-trips
# ---------------------------------------------------------------------------

def test_plan_artifact_roundtrip_exact():
    m = _tiny("mobilenetv3")
    cluster = make_pi_cluster([1.5, 1.2, 0.8])
    pico = plan(m.graph, cluster, m.input_size)
    s = artifacts.plan_to_json(pico)
    back = artifacts.plan_from_json(s)
    assert artifacts.plan_to_dict(back) == artifacts.plan_to_dict(pico)
    assert simulate(back.pipeline, 32) == simulate(pico.pipeline, 32)
    assert back.period == pico.period and back.latency == pico.latency


def test_partition_and_cost_table_roundtrip():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    pico = plan(m.graph, cluster, m.input_size)
    pr = artifacts.partition_from_json(
        artifacts.partition_to_json(pico.partition))
    assert [p.nodes for p in pr] == [p.nodes for p in pico.partition]
    assert pr.objective == pico.partition.objective

    table = CostTable({frozenset({"conv1"}): 1.5,
                       frozenset({"conv2", "pool1"}): 0.75}, default=1.1)
    back = artifacts.cost_table_from_json(artifacts.cost_table_to_json(table))
    assert back.ratios == table.ratios and back.default == table.default


def test_artifact_envelope_guards():
    table = CostTable({frozenset({"a"}): 2.0})
    d = json.loads(artifacts.cost_table_to_json(table))
    with pytest.raises(ValueError):
        artifacts.plan_from_json(json.dumps(d))        # wrong kind
    d["version"] = artifacts.SCHEMA_VERSION + 1
    with pytest.raises(ValueError):
        artifacts.cost_table_from_json(json.dumps(d))  # future version


def test_model_roundtrip_preserves_init_and_forward():
    m = _tiny("squeezenet")
    back = artifacts.model_from_dict(artifacts.model_to_dict(m))
    assert back.name == m.name
    assert list(back.graph.layers) == list(m.graph.layers)
    assert back.graph.edges == m.graph.edges
    p1 = m.init(jax.random.PRNGKey(0))
    p2 = back.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
    o1, o2 = m.forward(p1, x), back.forward(p2, x)
    for k in o1:
        np.testing.assert_array_equal(np.asarray(o1[k]), np.asarray(o2[k]))


# ---------------------------------------------------------------------------
# Deployment save/load: bit-identical, zero re-plan / re-calibration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,scale", [
    ("squeezenet", 0.25), ("mobilenetv3", 0.25), ("vgg16", 0.125),
])
def test_save_load_bit_identical(tmp_path, name, scale, monkeypatch):
    m = _tiny(name, size=48, scale=scale)
    cluster = make_pi_cluster([1.5, 1.0, 0.8])
    dep = repro.compile(m, cluster)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 48, 48, 3))
    out1 = dep.run(x)
    sim1 = dep.simulate(32)
    path = dep.save(tmp_path / f"{name}.json")

    # loading must touch neither the planner nor the calibrator — patch
    # both the defining modules and deployment.py's module-level binding
    import repro.api.deployment as deployment_mod
    import repro.core.planner as planner_mod
    import repro.exec.calibrate as calibrate_mod

    def _boom(*a, **k):
        raise AssertionError("re-planning/re-calibration on load")

    monkeypatch.setattr(planner_mod, "plan_with_spec", _boom)
    monkeypatch.setattr(planner_mod, "plan", _boom)
    monkeypatch.setattr(deployment_mod, "plan_with_spec", _boom)
    monkeypatch.setattr(calibrate_mod, "calibrate_plan", _boom)

    dep2 = repro.Deployment.load(path)
    assert dep2.simulate(32) == sim1
    assert artifacts.plan_to_dict(dep2.pico) == artifacts.plan_to_dict(dep.pico)
    out2 = dep2.run(x)
    assert out1.keys() == out2.keys()
    for k in out1:
        np.testing.assert_array_equal(np.asarray(out1[k]),
                                      np.asarray(out2[k]))


def test_artifact_refuses_reserved_string_names():
    from repro.core.graph import Graph, LayerSpec
    g = Graph()
    g.add(LayerSpec("NaN", "conv", (1, 1), (1, 1), (0, 0), 3, 4))
    with pytest.raises(ValueError, match="collides"):
        artifacts.dumps_payload("model", artifacts.graph_to_dict(g))


def test_compile_key_seeds_weights_without_calibration(tmp_path):
    m = _tiny("squeezenet", size=48)
    cluster = make_pi_cluster([1.5, 1.0])
    k = jax.random.PRNGKey(7)
    dep = repro.compile(m, cluster, key=k)
    assert dep.params is not None
    ref = m.init(jax.random.PRNGKey(7))
    for name in ref:
        for leaf in ref[name]:
            np.testing.assert_array_equal(
                np.asarray(ref[name][leaf]),
                np.asarray(dep.params[name][leaf]))
    # trained/custom weights reattach on load
    path = dep.save(tmp_path / "d.json")
    dep2 = repro.Deployment.load(path, params=dep.params)
    assert dep2.params is dep.params


def test_save_load_preserves_cost_table(tmp_path):
    m = _tiny("vgg16", scale=0.125)
    cluster = make_pi_cluster([1.5, 1.0])
    dep = repro.compile(m, cluster,
                        exec_spec=ExecSpec(calibrate=True,
                                           calibrate_iters=1))
    assert dep.cost_table is not None and len(dep.cost_table) > 0
    path = dep.save(tmp_path / "cal.json")
    dep2 = repro.Deployment.load(path)
    assert dep2.cost_table.ratios == dep.cost_table.ratios
    assert dep2.cost_table.default == dep.cost_table.default
    assert dep2.exec_spec == dep.exec_spec
    assert dep2.plan_spec == dep.plan_spec


def test_deployment_replan_reuses_piece_chain():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    dep = repro.compile(m, cluster)
    shrunk = make_pi_cluster([1.5, 1.0])
    dep2 = dep.replan(shrunk)
    assert [p.nodes for p in dep2.partition] == \
        [p.nodes for p in dep.partition]
    assert dep2.partition.states_explored == dep.partition.states_explored
    used = {d.name for st in dep2.pipeline.stages for d in st.devices}
    assert used == {d.name for d in shrunk.devices}


def test_deployment_online_forms():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    dep = repro.compile(m, cluster)
    # timing-only runtime (no params loaded)
    rep = dep.runtime(DeploySpec(seed=0)).run(8)
    assert rep.completed == 8
    # closed-form server reuses the deployment's plan object
    srv = dep.server()
    assert srv.pico is dep.pico
    from repro.data.pipeline import Request
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
    outs, stats = srv.load().serve([Request(0, 0.0, x)])
    assert stats.served == 1 and outs[0]
    # streaming server accepts a DeploySpec
    srv2 = dep.server(DeploySpec(seed=1), streaming=True)
    outs2, stats2 = srv2.load().serve([Request(0, 0.0, x)])
    assert stats2.served == 1
    for k in outs[0]:
        np.testing.assert_array_equal(np.asarray(outs[0][k]),
                                      np.asarray(outs2[0][k]))
    # deploy knobs have no closed-form counterpart: loud, not silent
    with pytest.raises(TypeError):
        dep.server(DeploySpec(max_batch=4))


def test_server_load_keeps_deployment_params():
    m = _tiny("squeezenet", size=48)
    cluster = make_pi_cluster([1.5, 1.0])
    dep = repro.compile(m, cluster, key=jax.random.PRNGKey(5))
    srv = dep.server().load()           # the canonical load().serve() flow
    assert srv.params is dep.params
    srv2 = dep.server().load(jax.random.PRNGKey(9))   # explicit re-key wins
    assert srv2.params is not dep.params


def test_run_scan_batch_matches_per_frame():
    m = _tiny("squeezenet", size=48)
    cluster = make_pi_cluster([1.5, 1.0])
    xs = [jax.random.normal(jax.random.PRNGKey(i), (1, 48, 48, 3))
          for i in range(3)]
    dep = repro.compile(m, cluster)
    scanned = dep.run(xs)
    assert len(scanned) == 3
    looped = repro.compile(
        m, cluster, exec_spec=ExecSpec(scan_batch=False))
    looped.params = dep.params
    plain = looped.run(xs)
    for a, b in zip(scanned, plain):
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                       rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# deprecation shims: warn exactly once, bit-identical results
# ---------------------------------------------------------------------------

def _one_deprecation(wlist):
    return [w for w in wlist if issubclass(w.category, DeprecationWarning)]


def test_plan_legacy_kwargs_warn_exactly_once():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = plan(m.graph, cluster, m.input_size, t_lim=0.05)
        legacy2 = plan(m.graph, cluster, m.input_size, t_lim=0.05)
    assert len(_one_deprecation(w)) == 1
    spec_plan = plan(m.graph, cluster, m.input_size,
                     spec=PlanSpec(t_lim=0.05))
    assert _canon(legacy) == _canon(spec_plan) == _canon(legacy2)


def test_replan_legacy_t_lim_warns_once():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    prev = plan(m.graph, cluster, m.input_size)
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        a = replan(m.graph, cluster, m.input_size, prev=prev, t_lim=0.05)
        replan(m.graph, cluster, m.input_size, prev=prev, t_lim=0.05)
    assert len(_one_deprecation(w)) == 1
    b = replan(m.graph, cluster, m.input_size, prev=prev,
               spec=PlanSpec(t_lim=0.05))
    assert _canon(a) == _canon(b)


def test_pipeline_server_legacy_kwargs_warn_once_and_match():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = PipelineServer(m, cluster, t_lim=0.05)
        PipelineServer(m, cluster, t_lim=0.05)
    assert len(_one_deprecation(w)) == 1
    fresh = PipelineServer(m, cluster, plan_spec=PlanSpec(t_lim=0.05))
    assert _canon(legacy.pico) == _canon(fresh.pico)


def test_pipeline_runtime_legacy_kwargs_warn_once_and_match():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rt1 = PipelineRuntime(m.graph, cluster, m.input_size, t_lim=0.05)
        rt2 = PipelineRuntime(m.graph, cluster, m.input_size, t_lim=0.05)
    assert len(_one_deprecation(w)) == 1
    rt3 = PipelineRuntime(m.graph, cluster, m.input_size,
                          plan_spec=PlanSpec(t_lim=0.05))
    assert _canon(rt1.pico) == _canon(rt2.pico) == _canon(rt3.pico)


def test_mixing_spec_and_legacy_kwargs_raises():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    with pytest.raises(TypeError):
        PipelineRuntime(m.graph, cluster, m.input_size, t_lim=0.05,
                        plan_spec=PlanSpec())
    with pytest.raises(TypeError):
        PipelineServer(m, cluster, backend="xla", exec_spec=ExecSpec())


# ---------------------------------------------------------------------------
# PartitionResult.from_pieces (honest reused-chain stats)
# ---------------------------------------------------------------------------

def test_from_pieces_honest_stats():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    full = plan(m.graph, cluster, m.input_size)
    pr = PartitionResult.from_pieces(full.partition.pieces)
    assert pr.objective == max(p.redundancy for p in pr.pieces)
    assert [p.index for p in pr.pieces] == list(range(len(pr.pieces)))
    with pytest.raises(ValueError):
        PartitionResult.from_pieces([])


def test_plan_with_pieces_keeps_honest_partition():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.0])
    full = plan(m.graph, cluster, m.input_size)
    reused = plan(m.graph, cluster, m.input_size,
                  pieces=full.partition.pieces)
    assert reused.partition.objective == full.partition.objective
    assert len(reused.partition) == len(full.partition)


def test_replan_carries_partition_provenance():
    m = _tiny("squeezenet")
    cluster = make_pi_cluster([1.5, 1.2, 1.0])
    prev = plan(m.graph, cluster, m.input_size)
    assert prev.partition.states_explored > 0
    new = replan(m.graph, make_pi_cluster([1.5, 1.0]), m.input_size,
                 prev=prev)
    # the reused chain keeps its true search stats instead of zeros
    assert new.partition.states_explored == prev.partition.states_explored
    assert new.partition.wall_time_s == prev.partition.wall_time_s
    assert new.partition.objective == prev.partition.objective


# ---------------------------------------------------------------------------
# scheduler through the spec surface
# ---------------------------------------------------------------------------

def test_scheduler_exec_spec_and_tenant_plan_spec():
    from repro.serving import SchedulerConfig, ServingScheduler, TenantConfig
    cluster = make_pi_cluster([1.5, 1.2, 1.0])
    tenants = [
        TenantConfig("a", zoo.squeezenet(input_size=(64, 64), scale=0.1),
                     plan_spec=PlanSpec()),
        TenantConfig("b", zoo.mobilenetv3(input_size=(64, 64), scale=0.25)),
    ]
    sched = ServingScheduler(tenants, cluster,
                             config=SchedulerConfig(seed=0),
                             exec_spec=ExecSpec())
    assert sched.backend is None
    from repro.data.pipeline import Request
    workload = {"a": [Request(i, 0.01 * i, None) for i in range(4)],
                "b": [Request(i, 0.01 * i, None) for i in range(4)]}
    report = sched.serve(workload)
    assert report.served == 8 and report.dropped_inflight == 0


def test_scheduler_legacy_backend_kwarg_warns_once():
    from repro.serving import ServingScheduler, TenantConfig
    cluster = make_pi_cluster([1.5, 1.0])
    tenants = [TenantConfig(
        "a", zoo.squeezenet(input_size=(64, 64), scale=0.1))]
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ServingScheduler(tenants, cluster, backend=None)
        ServingScheduler(tenants, cluster, backend=None)
    assert len(_one_deprecation(w)) == 1


# ---------------------------------------------------------------------------
# plan CLI
# ---------------------------------------------------------------------------

def test_plan_cli_save_load_validate(tmp_path, capsys):
    from repro.tools.plan import main
    out = tmp_path / "plan.json"
    assert main(["--model", "squeezenet", "--scale", "0.25",
                 "--input", "48", "--devices", "2",
                 "--out", str(out)]) == 0
    assert out.exists()
    assert main(["--load", str(out), "--validate"]) == 0
    text = capsys.readouterr().out
    assert "validate: schema v1 ok" in text


def test_top_level_exports():
    assert callable(repro.compile)
    assert repro.Deployment is not None
    assert repro.PlanSpec is PlanSpec
    assert "compile" in dir(repro)
