"""Property-style coverage for request queueing, admission control,
batching and tenant arbitration (serving.queueing).

Uses the optional-hypothesis shim: with hypothesis installed the
``@given`` properties fuzz the policies; without it they skip while the
plain unit tests still run.
"""

from collections import deque
from dataclasses import dataclass

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.serving.queueing import (OpenLoopGenerator, TenantQueue,
                                    WeightedArbiter, coalesce)


@dataclass
class Item:
    uid: int
    deadline: float | None = None


# ---------------------------------------------------------------------------
# coalesce: batch formation + deadline expiry
# ---------------------------------------------------------------------------

def test_coalesce_fifo_order_and_cap():
    q = deque(Item(i) for i in range(10))
    batch, expired = coalesce(q, now=0.0, max_batch=4)
    assert [b.uid for b in batch] == [0, 1, 2, 3]
    assert expired == []
    assert [x.uid for x in q] == [4, 5, 6, 7, 8, 9]


def test_coalesce_expires_only_past_deadline():
    q = deque([Item(0, deadline=1.0), Item(1, deadline=5.0),
               Item(2), Item(3, deadline=1.5)])
    batch, expired = coalesce(q, now=2.0, max_batch=10)
    assert [b.uid for b in batch] == [1, 2]
    assert [e.uid for e in expired] == [0, 3]
    assert not q


def test_coalesce_expired_do_not_count_against_cap():
    q = deque([Item(0, deadline=0.0), Item(1, deadline=0.0), Item(2),
               Item(3)])
    batch, expired = coalesce(q, now=1.0, max_batch=2)
    assert [b.uid for b in batch] == [2, 3]
    assert len(expired) == 2


@given(st.lists(st.tuples(st.booleans(), st.floats(0.0, 10.0)),
                min_size=0, max_size=40),
       st.integers(1, 8), st.floats(0.0, 10.0))
@settings(max_examples=60, deadline=None)
def test_coalesce_partition_property(spec, max_batch, now):
    """Every queued item ends up in exactly one of (batch, expired,
    still-queued); batch and expired preserve arrival order; nothing in
    the batch is past its deadline."""
    items = [Item(i, deadline=(d if has_dl else None))
             for i, (has_dl, d) in enumerate(spec)]
    q = deque(items)
    batch, expired = coalesce(q, now=now, max_batch=max_batch)
    assert len(batch) <= max_batch
    seen = [b.uid for b in batch] + [e.uid for e in expired] \
        + [x.uid for x in q]
    assert sorted(seen) == [i.uid for i in items]
    assert [b.uid for b in batch] == sorted(b.uid for b in batch)
    assert [e.uid for e in expired] == sorted(e.uid for e in expired)
    assert all(b.deadline is None or now <= b.deadline for b in batch)
    assert all(e.deadline is not None and now > e.deadline for e in expired)


# ---------------------------------------------------------------------------
# TenantQueue: admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_when_full():
    q = TenantQueue(max_queue=2)
    assert q.offer() and q.offer()
    assert not q.offer()
    assert (q.admitted, q.rejected, q.in_system) == (2, 1, 2)
    q.complete()
    assert q.offer()                      # slot freed by completion
    assert q.admitted == 3


def test_admission_accounting_balances():
    q = TenantQueue(max_queue=3)
    outcomes = [q.offer() for _ in range(5)]
    assert outcomes == [True, True, True, False, False]
    q.complete()
    q.expire()
    assert q.in_system == 1
    assert q.admitted == q.completed + q.expired + q.in_system


@given(st.lists(st.sampled_from(["offer", "complete", "expire"]),
                min_size=0, max_size=200),
       st.integers(1, 10))
@settings(max_examples=60, deadline=None)
def test_admission_invariants(ops, cap):
    """in_system never exceeds max_queue or goes negative, and the
    counter identity admitted == completed + expired + in_system holds
    under any interleaving."""
    q = TenantQueue(max_queue=cap)
    for op in ops:
        if op == "offer":
            q.offer()
        elif q.in_system > 0:
            getattr(q, op)()
        assert 0 <= q.in_system <= cap
        assert q.admitted == q.completed + q.expired + q.in_system


# ---------------------------------------------------------------------------
# WeightedArbiter: proportional grants, no starvation
# ---------------------------------------------------------------------------

def test_arbiter_grants_proportional_to_weights():
    arb = WeightedArbiter({"a": 3.0, "b": 1.0})
    for _ in range(400):
        arb.pick()
    assert abs(arb.grants["a"] - 300) <= 2
    assert abs(arb.grants["b"] - 100) <= 2


def test_arbiter_respects_eligibility():
    arb = WeightedArbiter({"a": 1.0, "b": 1.0})
    assert arb.pick({"b"}) == "b"
    assert arb.pick(set()) is None


def test_arbiter_new_tenant_does_not_monopolize():
    arb = WeightedArbiter({"a": 1.0})
    for _ in range(100):
        arb.pick()
    arb.add("b", 1.0)
    picks = [arb.pick() for _ in range(10)]
    # joined at the current floor: alternates instead of being handed
    # 100 rounds of accumulated credit
    assert picks.count("b") <= 6


@given(st.lists(st.floats(0.1, 20.0), min_size=1, max_size=6),
       st.integers(10, 300))
@settings(max_examples=60, deadline=None)
def test_arbiter_no_starvation(weights, rounds):
    """Over any horizon, every tenant's grant count is within one grant
    of its weight share — nobody starves no matter how skewed the
    weights are."""
    names = [f"t{i}" for i in range(len(weights))]
    arb = WeightedArbiter(dict(zip(names, weights)))
    for _ in range(rounds):
        arb.pick()
    total_w = sum(weights)
    for n, w in zip(names, weights):
        expected = rounds * w / total_w
        assert arb.grants[n] >= int(expected) - 1
        assert arb.grants[n] <= expected + 1 + len(weights)


# ---------------------------------------------------------------------------
# OpenLoopGenerator: seeded, ordered, bursty
# ---------------------------------------------------------------------------

def test_open_loop_deterministic_and_ordered():
    g1 = OpenLoopGenerator(rate_per_s=50.0, seed=7)
    g2 = OpenLoopGenerator(rate_per_s=50.0, seed=7)
    a, b = g1.arrivals(50), g2.arrivals(50)
    assert a == b
    assert a == sorted(a)
    assert OpenLoopGenerator(rate_per_s=50.0, seed=8).arrivals(50) != a


def test_open_loop_burst_raises_rate():
    base = OpenLoopGenerator(rate_per_s=20.0, seed=1)
    burst = OpenLoopGenerator(rate_per_s=20.0, seed=1, burst_factor=8.0,
                              burst_period_s=1.0, burst_duty=1.0)
    assert burst.arrivals(200)[-1] < base.arrivals(200)[-1]


def test_open_loop_requests_carry_payloads():
    gen = OpenLoopGenerator(rate_per_s=10.0, seed=0)
    reqs = gen.generate(5, make_payload=lambda rng, i: ("payload", i))
    assert [r.rid for r in reqs] == list(range(5))
    assert all(r.payload == ("payload", i) for i, r in enumerate(reqs))
