"""Transformer -> PICO graph export (DESIGN.md §4): Algorithm 1 must
treat full attention as a sync point (the Fig. 6 analogue) and the
planner must build balanced pipelines for the assigned archs."""

import pytest

from repro import configs
from repro.core import make_tpu_cluster, partition_graph, plan
from repro.models.graph_export import export_graph


def test_zamba2_attention_is_a_sync_point():
    cfg = configs.get("zamba2-2.7b")
    g = export_graph(cfg, seq_len=2048)
    assert g.width() == 1  # decoder chain
    res = partition_graph(g, (2048, 1), n_split=4, max_diameter=5)
    assert res.objective == 0
    for p in res.pieces:
        kinds = {g.layers[n].kind for n in p.nodes}
        # a global-RF attention never fuses below a finite-halo mixer
        if "attn" in kinds:
            assert not kinds & {"conv1d", "ssd"}, kinds


@pytest.mark.parametrize("name", ["llama3.2-1b", "mixtral-8x7b",
                                  "mamba2-370m"])
def test_planner_balances_decoder_pipeline(name):
    cfg = configs.get(name)
    g = export_graph(cfg, seq_len=1024)
    cluster = make_tpu_cluster(4)
    p = plan(g, cluster, (1024, 1), max_diameter=2)
    assert len(p.pipeline.stages) >= 2
    times = [st.cost.total for st in p.pipeline.stages]
    assert max(times) <= 2.5 * (sum(times) / len(times))  # balanced
    # all vertices covered exactly once
    seen = set()
    for st in p.pipeline.stages:
        assert not (seen & st.nodes)
        seen |= st.nodes
    assert seen == set(g.layers)


def test_swa_has_finite_halo():
    cfg = configs.get("mixtral-8x7b")
    g = export_graph(cfg, seq_len=8192)
    attn = g.layers["l0.attn"]
    assert attn.kind == "swa" and not attn.global_rf
    assert attn.kernel[0] == cfg.sliding_window
