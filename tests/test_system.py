"""End-to-end behaviour tests for the paper's system: plan -> execute ->
serve, plus training/serving/checkpoint substrate."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import make_pi_cluster, plan, simulate
from repro.data.pipeline import RequestStream, TokenStream
from repro.models.cnn import zoo
from repro.models.transformer import model as M
from repro.serving import PipelineServer, generate
from repro.training import checkpoint
from repro.training.loop import train


def test_full_pico_flow_with_simulation():
    m = zoo.squeezenet(input_size=(96, 96), scale=0.15)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    p = plan(m.graph, cluster, m.input_size)
    assert p.period > 0 and p.latency >= p.period
    rep = simulate(p.pipeline, frames=32)
    assert 0 < rep.avg_utilization <= 1.0
    assert rep.period <= p.latency + 1e-9
    # all devices assigned exactly once
    names = [d.name for st in p.pipeline.stages for d in st.devices]
    assert sorted(names) == sorted(d.name for d in cluster.devices)


def test_pipeline_server_serves_requests():
    m = zoo.vgg16(input_size=(96, 96), scale=0.1, head=False)
    cluster = make_pi_cluster([1.5, 1.0])
    server = PipelineServer(m, cluster).load()
    H, W = m.input_size[1], m.input_size[0]
    reqs = RequestStream(rate_per_s=5.0).generate(
        4, lambda rng, i: jnp.asarray(
            rng.standard_normal((1, H, W, 3)).astype(np.float32)))
    outs, stats = server.serve(reqs)
    assert stats.served == 4
    assert stats.model_throughput_per_min > 0
    ref = m.forward(server.params, reqs[0].payload)
    for k in ref:
        np.testing.assert_allclose(np.asarray(outs[0][k]),
                                   np.asarray(ref[k]), rtol=1e-5,
                                   atol=1e-5)


def test_lm_generate_matches_stepwise_argmax():
    cfg = configs.get("llama3.2-1b").reduced(n_layers=2, d_model=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    toks = generate(cfg, params, prompt, n_new=4)
    assert toks.shape == (2, 4)
    # reference: teacher-forced argmax using full forward each step
    seq = prompt
    for t in range(4):
        logits = M.forward(cfg, params, {"tokens": seq}, remat=False)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(toks[:, t]),
                                      np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


def test_training_reduces_loss_and_checkpoints(tmp_path):
    cfg = configs.get("llama3.2-1b").reduced(n_layers=2, d_model=64)
    rep = train(cfg, steps=30, batch=4, seq=32, lr=3e-3, log_every=0,
                ckpt_path=str(tmp_path / "ck"))
    assert np.isfinite(rep.final_loss)
    assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5])
    # roundtrip
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    loaded = checkpoint.load(tmp_path / "ck", params)
    assert all(a.shape == b.shape for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(loaded)))


def test_token_stream_learnable_structure():
    s = TokenStream(vocab=97, batch=2, seq=16, seed=0)
    b = next(iter(s))
    assert b["tokens"].shape == (2, 16)
    # labels are the shifted continuation of the same pattern
    assert b["labels"].shape == (2, 16)
    assert int(b["tokens"].max()) < 97
