"""Pipeline simulator invariants (paper Eq. 12 quantities), property-
tested over random stage-time configurations."""

from _hypothesis_compat import given, settings, st

from repro.core import make_pi_cluster, plan, simulate
from repro.core.cost import SegmentCost, StageCost, Device
from repro.core.pipeline_dp import PipelinePlan, StagePlan
from repro.models.cnn import zoo


def _plan_from_times(times):
    stages = []
    for i, t in enumerate(times):
        dev = Device(f"d{i}", 1e9)
        seg = SegmentCost(frozenset({f"n{i}"}), [t * 1e9], t * 1e9,
                          [0.0], [0.0], 0, [0.0])
        stages.append(StagePlan(i, i, [dev], frozenset({f"n{i}"}),
                                StageCost(t, 0.0, [t], seg), [1.0]))
    return PipelinePlan(stages, max(times), sum(times))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(1e-4, 10.0), min_size=1, max_size=6),
       st.integers(2, 64))
def test_steady_state_period_is_max_stage(times, frames):
    rep = simulate(_plan_from_times(times), frames=frames)
    assert abs(rep.period - max(times)) < 1e-9
    # makespan = warmup latency + (frames-1) * period
    expect = sum(times) + (frames - 1) * max(times)
    assert abs(rep.makespan - expect) < 1e-6
    for d in rep.devices:
        assert 0.0 <= d.utilization <= 1.0 + 1e-9
        assert d.energy_j >= 0


def test_simulation_matches_plan_on_real_model():
    m = zoo.squeezenet(input_size=(96, 96), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.0, 0.8])
    p = plan(m.graph, cluster, m.input_size)
    rep = simulate(p.pipeline, frames=64)
    assert abs(rep.period - p.period) < 1e-9
    assert rep.throughput_per_min > 0
    # the bottleneck stage's devices are the busiest
    bot = max(range(len(p.pipeline.stages)),
              key=lambda i: p.pipeline.stages[i].cost.total)
    bot_util = max(d.utilization for d in rep.devices if d.stage == bot)
    assert bot_util >= max(d.utilization for d in rep.devices) - 1e-9
