"""Bit-exactness of the pipelined/tiled execution vs monolithic forward.

This is the system's core correctness property (paper §5.3: split and
stitch must be lossless), property-tested over random CNN chains with
hypothesis and over the real zoo DAGs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip cleanly without hypothesis (requirements-dev.txt);
# the plain zoo-model bit-exactness tests below always run
from _hypothesis_compat import given, settings, st

from repro.core import make_pi_cluster, plan
from repro.models.cnn import zoo
from repro.models.cnn.builder import GB
from repro.pipeline import PipelineRunner
from repro.pipeline.stage import StageExecutor


@pytest.mark.parametrize("name,kw", [
    ("resnet34", dict(input_size=(96, 96), scale=0.1)),
    ("inceptionv3", dict(input_size=(96, 96), scale=0.1)),
    ("nasnet", dict(n_cells=3, input_size=(64, 64), scale=0.15)),
])
def test_pipeline_equals_monolithic(name, kw):
    m = zoo.build(name, **kw)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    p = plan(m.graph, cluster, m.input_size)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, m.input_size[1], m.input_size[0], 3))
    ref = m.forward(params, x)
    out = PipelineRunner(m, p.pipeline)(params, x)
    for k in ref:
        assert not np.isnan(np.asarray(ref[k])).any()
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


def test_uneven_multiway_tile_split():
    m = zoo.resnet34(input_size=(96, 96), scale=0.1)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 96, 3))
    ref = m.forward(params, x)
    ex = StageExecutor(m, frozenset(m.graph.layers),
                       [0.35, 0.3, 0.2, 0.15])
    out = ex(params, {}, x)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    st.lists(st.sampled_from(
        [("conv", 3, 1, 1), ("conv", 1, 1, 0), ("conv", 5, 1, 2),
         ("conv", 3, 2, 1), ("pool", 2, 2, 0), ("conv", 3, 1, 0)]),
        min_size=2, max_size=5),
    st.integers(2, 4),
    st.booleans(),
)
def test_random_chain_tiled_exact(ops, parts, with_skip):
    """Random small chains (optionally with an add-skip) tile exactly."""
    b = GB("rand", (24, 24))
    x = b.conv(None, 4, 3, p=1)
    skip_src = x
    depth_since_skip = 0
    for kind, k, s, p in ops:
        if kind == "conv":
            x = b.conv(x, 4, k, s=s, p=p)
        else:
            x = b.pool(x, k, s)
        depth_since_skip += 1
        if with_skip and depth_since_skip == 1 and s == 1 and \
                b.sz[x] == b.sz[skip_src]:
            x = b.add([x, skip_src])
    m = b.done()
    params = m.init(jax.random.PRNGKey(0))
    img = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 24, 3))
    ref = m.forward(params, img)
    sink_w = min(m.full_sizes[s][0] for s in m.graph.sinks())
    if sink_w < parts:
        return
    ex = StageExecutor(m, frozenset(m.graph.layers), [1 / parts] * parts)
    out = ex(params, {}, img)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)
