"""Bit-exactness of the pipelined/tiled execution vs monolithic forward.

This is the system's core correctness property (paper §5.3: split and
stitch must be lossless), property-tested over random CNN chains with
hypothesis and over the real zoo DAGs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip cleanly without hypothesis (requirements-dev.txt);
# the plain zoo-model bit-exactness tests below always run
from _hypothesis_compat import given, settings, st

from repro.core import make_pi_cluster, plan
from repro.models.cnn import zoo
from repro.models.cnn.builder import GB
from repro.pipeline import PipelineRunner
from repro.pipeline.stage import StageExecutor

# tiny-but-representative build of every zoo model (pallas runs in
# interpret mode on CPU, so sizes are kept small)
ZOO_TINY = {
    "vgg16": dict(input_size=(40, 40), scale=0.1, head=False),
    "yolov2": dict(input_size=(64, 64), scale=0.05),
    "resnet34": dict(input_size=(64, 64), scale=0.1),
    "inceptionv3": dict(input_size=(96, 96), scale=0.1),
    "squeezenet": dict(input_size=(64, 64), scale=0.1),
    "mobilenetv3": dict(input_size=(64, 64), scale=0.1),
    "nasnet": dict(n_cells=2, input_size=(48, 48), scale=0.15),
}


@pytest.mark.parametrize("name", sorted(ZOO_TINY))
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_compiled_stage_bit_exact_with_eager(name, backend):
    """The `repro.exec` compiled stage path reproduces the seed's eager
    tile loop for every zoo model on both backends: bit-for-bit on xla;
    to ULP tolerance on pallas, which runs via interpret on CPU where
    whole-stage fusion can reassociate the emulated kernel's ops."""
    m = zoo.build(name, **ZOO_TINY[name])
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, m.input_size[1], m.input_size[0], 3))
    fracs = [0.4, 0.35, 0.25]
    eager = StageExecutor(m, frozenset(m.graph.layers), fracs,
                          backend=backend, mode="eager")(params, {}, x)
    compiled = StageExecutor(m, frozenset(m.graph.layers), fracs,
                             backend=backend)(params, {}, x)
    assert eager.keys() == compiled.keys()
    for k in eager:
        if backend == "xla":
            np.testing.assert_array_equal(np.asarray(compiled[k]),
                                          np.asarray(eager[k]))
        else:
            # interpret-mode pallas emulates the kernel with XLA ops; on
            # CPU the whole-stage jit may fuse those ops differently
            # than the seed's standalone-jit kernel call, shifting deep
            # models (mobilenetv3: ~50 layers) by a few ULP — everything
            # else is identical
            np.testing.assert_allclose(np.asarray(compiled[k]),
                                       np.asarray(eager[k]),
                                       rtol=1e-6, atol=1e-7)
    # and both match the monolithic reference numerically
    ref = m.forward(params, x)
    for k in ref:
        np.testing.assert_allclose(np.asarray(compiled[k]),
                                   np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", sorted(ZOO_TINY))
def test_zoo_pallas_runs_without_fallbacks(name):
    """The generalized Pallas kernel is the *only* conv path: every zoo
    model — strided stems, 1x1 projections, channel tails, fused
    conv->pool chains — runs the pallas backend with ZERO recorded
    ``conv.fallback``s, matching the XLA reference to ULP tolerance
    (interpret mode on CPU)."""
    from repro.kernels.conv2d.ops import fallback_count, reset_fallbacks
    m = zoo.build(name, **ZOO_TINY[name])
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, m.input_size[1], m.input_size[0], 3))
    reset_fallbacks()
    ref = m.forward(params, x, backend="xla")
    out = m.forward(params, x, backend="pallas")          # monolithic
    tiled = StageExecutor(m, frozenset(m.graph.layers), [0.6, 0.4],
                          backend="pallas")(params, {}, x)  # fused+tiled
    assert fallback_count() == 0, \
        f"{name}: pallas backend fell back {fallback_count()} time(s)"
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(tiled[k]), np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)


def test_fused_conv_pool_chain_matches_unfused():
    """fusable_chains finds the zoo's conv->pool chains and the fused
    lowering matches the unfused compiled path to ULP tolerance."""
    from repro.exec.compiler import fusable_chains
    m = zoo.build("vgg16", **ZOO_TINY["vgg16"])
    chains = fusable_chains(m.graph, frozenset(m.graph.layers))
    assert len(chains) >= 4   # vgg16: one fusable pool per conv block
    for conv, pool in chains.items():
        assert m.graph.layers[conv].kind == "conv"
        assert m.graph.layers[pool].kind == "pool"
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 40, 40, 3))
    fused = StageExecutor(m, frozenset(m.graph.layers), [0.5, 0.5],
                          backend="pallas")(params, {}, x)
    unfused = StageExecutor(m, frozenset(m.graph.layers), [0.5, 0.5],
                            backend="pallas", fuse=False)(params, {}, x)
    for k in fused:
        np.testing.assert_allclose(np.asarray(fused[k]),
                                   np.asarray(unfused[k]),
                                   rtol=1e-6, atol=1e-7)


def test_fuse_flag_is_part_of_cache_key():
    """Fused and unfused executables of the same stage must not collide
    in the executable cache."""
    from repro.exec import clear_cache, cache_stats
    m = zoo.build("vgg16", **ZOO_TINY["vgg16"])
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 40, 40, 3))
    clear_cache()
    StageExecutor(m, frozenset(m.graph.layers), [1.0],
                  backend="pallas")(params, {}, x)
    StageExecutor(m, frozenset(m.graph.layers), [1.0],
                  backend="pallas", fuse=False)(params, {}, x)
    assert cache_stats().misses == 2   # distinct keys -> two builds
    clear_cache()


def test_compiled_multi_stage_plan_bit_exact_with_eager():
    """Whole-plan check: compiled and eager runners agree stage by stage
    on a real PICO plan (not just the single fused stage)."""
    m = zoo.resnet34(input_size=(96, 96), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    p = plan(m.graph, cluster, m.input_size)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 96, 3))
    out_c = PipelineRunner(m, p.pipeline)(params, x)
    out_e = PipelineRunner(m, p.pipeline, mode="eager")(params, x)
    for k in out_c:
        np.testing.assert_array_equal(np.asarray(out_c[k]),
                                      np.asarray(out_e[k]))


@pytest.mark.parametrize("name,kw", [
    ("resnet34", dict(input_size=(96, 96), scale=0.1)),
    ("inceptionv3", dict(input_size=(96, 96), scale=0.1)),
    ("nasnet", dict(n_cells=3, input_size=(64, 64), scale=0.15)),
])
def test_pipeline_equals_monolithic(name, kw):
    m = zoo.build(name, **kw)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    p = plan(m.graph, cluster, m.input_size)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, m.input_size[1], m.input_size[0], 3))
    ref = m.forward(params, x)
    out = PipelineRunner(m, p.pipeline)(params, x)
    for k in ref:
        assert not np.isnan(np.asarray(ref[k])).any()
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


def test_uneven_multiway_tile_split():
    m = zoo.resnet34(input_size=(96, 96), scale=0.1)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 96, 3))
    ref = m.forward(params, x)
    ex = StageExecutor(m, frozenset(m.graph.layers),
                       [0.35, 0.3, 0.2, 0.15])
    out = ex(params, {}, x)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    st.lists(st.sampled_from(
        [("conv", 3, 1, 1), ("conv", 1, 1, 0), ("conv", 5, 1, 2),
         ("conv", 3, 2, 1), ("pool", 2, 2, 0), ("conv", 3, 1, 0)]),
        min_size=2, max_size=5),
    st.integers(2, 4),
    st.booleans(),
)
def test_random_chain_tiled_exact(ops, parts, with_skip):
    """Random small chains (optionally with an add-skip) tile exactly."""
    b = GB("rand", (24, 24))
    x = b.conv(None, 4, 3, p=1)
    skip_src = x
    depth_since_skip = 0
    for kind, k, s, p in ops:
        if kind == "conv":
            x = b.conv(x, 4, k, s=s, p=p)
        else:
            x = b.pool(x, k, s)
        depth_since_skip += 1
        if with_skip and depth_since_skip == 1 and s == 1 and \
                b.sz[x] == b.sz[skip_src]:
            x = b.add([x, skip_src])
    m = b.done()
    params = m.init(jax.random.PRNGKey(0))
    img = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 24, 3))
    ref = m.forward(params, img)
    sink_w = min(m.full_sizes[s][0] for s in m.graph.sinks())
    if sink_w < parts:
        return
    ex = StageExecutor(m, frozenset(m.graph.layers), [1 / parts] * parts)
    out = ex(params, {}, img)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)
