"""Shared fixtures.  NOTE: no XLA device-count flags here by design —
smoke tests and benches must see the real (single) CPU device; only
launch/dryrun.py forces 512 host devices (in its own process).
"""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def assert_trees_close(a, b, rtol=1e-5, atol=1e-5):
    import jax
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=rtol, atol=atol)
